//! The coordinator's listening endpoint: bind **one** socket, let
//! workers dial in and *register*. This inverts the PR-3/PR-4 spawn
//! design (one private listener per spawned child) into the shape real
//! distributed deployments have: the coordinator is a server address,
//! and the workers — launched by anything: `spawn_fleet`, a shell loop,
//! an orchestrator on another host — connect to it and claim a worker
//! index.
//!
//! Registration protocol (one dialer, coordinator side):
//!
//! 1. accept the connection (bounded, non-blocking accept loop),
//! 2. read the hello (magic, `PROTOCOL_VERSION`, claimed worker index),
//! 3. validate: bad magic, a version mismatch, an out-of-range index,
//!    or a duplicate claim is refused **loudly** — a typed
//!    [`protocol::RegisterRefusal`] goes back to the dialer in a reject
//!    frame, and the same refusal fails the whole bring-up fast (the
//!    registration window is strict: every dialer that *speaks* must be
//!    one of ours — though a connection that closes before saying hello
//!    is mere network noise, logged and dropped),
//! 4. ack the registration (status + the coordinator's version, closing
//!    the version negotiation), ship the worker's batched
//!    [`protocol::Op::LoadShard`] frame, and collect the per-machine
//!    live-count acks.
//!
//! Handshakes run **concurrently** on a bounded pool while the accept
//! loop keeps accepting, so bring-up wall-clock stays O(m/w) whatever
//! launches the workers. The listener is **not** consumed by
//! [`Endpoint::accept_fleet`] (protocol v4): it stays bound for the
//! fleet's lifetime, and [`Endpoint::accept_rejoins`] re-opens the
//! registration path after bring-up so a relaunched (or late-joining)
//! worker can claim a *dead* worker's index and have its shards
//! re-shipped from the coordinator's retained copy. Post-bring-up
//! refusals are logged and dropped instead of failing anything — a
//! stray dialer must not kill a running fleet. All registration
//! traffic is handshake, not the paper's communication — it lands in
//! the links' raw byte counters but never in the fleet's protocol
//! meters.

use crate::transport::process::{read_timeout, WorkerLink, WorkerSpec};
use crate::transport::protocol::{self, RegisterRefusal};
use crate::util::error::{Context, Error, Result};
use crate::util::sync::{
    self, RankedMutex, REGISTRATION_ERROR, REGISTRATION_LINKS, REGISTRATION_QUEUE,
    REGISTRATION_SPEC,
};
use crate::{bail, format_err};
use std::io::ErrorKind;
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Bound on the first read of a new connection (the hello). A real
/// worker sends its 16-byte hello immediately after connecting, so
/// this can be tight — which also bounds how long a silent stray
/// (scanner, health check) can occupy a handshake thread.
const HELLO_TIMEOUT: Duration = Duration::from_secs(10);

/// Bound on the post-hello handshake reads (shard ack): generous
/// enough to decode a multi-hundred-MB shard batch, finite so a
/// registered-but-stuck worker cannot hang bring-up forever.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(60);

/// Cap on concurrent registration handshakes: enough to keep bring-up
/// O(m/w)-parallel at any realistic fleet size without unbounded
/// thread fan-out on a huge one. The pool always has a couple of
/// threads beyond the expected worker count, so a silent stray
/// occupying one (for up to [`HELLO_TIMEOUT`]) cannot starve a small
/// fleet's real dialers.
const MAX_REGISTRATION_CONCURRENCY: usize = 32;

/// Spare handshake threads beyond the expected worker count (see
/// [`MAX_REGISTRATION_CONCURRENCY`]).
const SPARE_REGISTRATION_THREADS: usize = 2;

/// Cap on the hello frame a brand-new, untrusted connection may claim:
/// a real hello is exactly 16 bytes; a little slack lets a runt or
/// overlong-but-small frame reach `decode_hello` for a typed refusal,
/// while an adversarial 4 GiB length prefix is dropped as noise before
/// any allocation.
const HELLO_MAX_FRAME: usize = 64;

/// Distinguishes concurrent endpoints in one coordinator process when
/// naming Unix socket paths.
static ENDPOINT_NONCE: AtomicU64 = AtomicU64::new(0);

/// One end of a process link: a Unix or TCP stream. Framing is the
/// shared `transport::{write_frame, read_frame}` pair the loopback TCP
/// transport also uses — one codec, one place to change it.
pub(crate) enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    pub(crate) fn send_frame(&mut self, payload: &[u8]) -> Result<()> {
        sync::assert_no_locks_held("a process-transport socket write");
        match self {
            Stream::Tcp(s) => crate::transport::write_frame(s, payload, "process transport"),
            #[cfg(unix)]
            Stream::Unix(s) => crate::transport::write_frame(s, payload, "process transport"),
        }
    }

    pub(crate) fn recv_frame(&mut self) -> Result<Vec<u8>> {
        sync::assert_no_locks_held("a process-transport socket read");
        match self {
            Stream::Tcp(s) => crate::transport::read_frame(s, "process transport"),
            #[cfg(unix)]
            Stream::Unix(s) => crate::transport::read_frame(s, "process transport"),
        }
    }

    /// Length-capped receive for frames from a peer not yet trusted
    /// (the registration hello): an adversarial length prefix is
    /// refused before any allocation.
    pub(crate) fn recv_frame_bounded(&mut self, max_len: usize) -> Result<Vec<u8>> {
        sync::assert_no_locks_held("a process-transport socket read");
        match self {
            Stream::Tcp(s) => {
                crate::transport::read_frame_bounded(s, max_len, "process transport")
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                crate::transport::read_frame_bounded(s, max_len, "process transport")
            }
        }
    }

    pub(crate) fn set_read_timeout(&self, t: Option<Duration>) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(t).context("set_read_timeout"),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(t).context("set_read_timeout"),
        }
    }

    pub(crate) fn set_write_timeout(&self, t: Option<Duration>) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(t).context("set_write_timeout"),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_write_timeout(t).context("set_write_timeout"),
        }
    }

    /// A second handle onto the same socket (`try_clone`), kept by the
    /// thread that OWNS teardown while another thread blocks in
    /// [`Stream::recv_frame`]/[`Stream::send_frame`]. `None` when the
    /// clone fails — teardown then falls back to detaching.
    pub(crate) fn breaker(&self) -> Option<StreamBreaker> {
        match self {
            Stream::Tcp(s) => s.try_clone().ok().map(StreamBreaker::Tcp),
            #[cfg(unix)]
            Stream::Unix(s) => s.try_clone().ok().map(StreamBreaker::Unix),
        }
    }
}

/// The unblocking half of a [`Stream`]: shutting the socket down from
/// here turns a blocked read/write on the owning thread into an
/// immediate error. This is what makes I/O-thread teardown *bounded* —
/// a wedged peer (or a SIGKILLed worker whose socket lingers) cannot
/// hold a blocking `recv` hostage past the shutdown grace window.
pub(crate) enum StreamBreaker {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl StreamBreaker {
    /// Shut both directions down, best-effort: an already-closed socket
    /// is fine — the goal is only that no blocking call survives this.
    pub(crate) fn shutdown(&self) {
        let _ = match self {
            StreamBreaker::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            #[cfg(unix)]
            StreamBreaker::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn set_nonblocking(&self, v: bool) -> Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(v).context("set_nonblocking"),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(v).context("set_nonblocking"),
        }
    }

    /// One non-blocking accept attempt: `Ok(Some)` on a connection,
    /// `Ok(None)` when nobody is dialing right now.
    fn try_accept(&self) -> Result<Option<Stream>> {
        let accepted = match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| {
                s.set_nodelay(true).ok();
                Stream::Tcp(s)
            }),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
        };
        match accepted {
            Ok(stream) => {
                match &stream {
                    Stream::Tcp(s) => s.set_nonblocking(false).context("set_nonblocking")?,
                    #[cfg(unix)]
                    Stream::Unix(s) => s.set_nonblocking(false).context("set_nonblocking")?,
                }
                Ok(Some(stream))
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e).context("endpoint: accept failed"),
        }
    }
}

/// Accept one connection on a TCP listener with a deadline — the
/// single-link helper the loopback transport's `pair()` builds on.
pub(crate) fn accept_one_with_deadline(
    listener: &TcpListener,
    timeout: Duration,
) -> Result<TcpStream> {
    listener
        .set_nonblocking(true)
        .context("endpoint: set_nonblocking")?;
    let deadline = Instant::now() + timeout;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false)
                    .context("endpoint: accepted stream set_nonblocking")?;
                return Ok(s);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!("accept timed out after {timeout:?} (peer never connected)");
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => return Err(e).context("endpoint: accept failed"),
        }
    }
}

/// The coordinator's bound listener plus the address workers dial. Bind
/// first (so the port is known and can be handed to whatever launches
/// the workers), bring the fleet up with [`Endpoint::accept_fleet`],
/// and keep it for the fleet's lifetime: the same endpoint later
/// admits crash-rejoins and late joiners via
/// [`Endpoint::accept_rejoins`].
pub struct Endpoint {
    listener: Listener,
    connect_addr: String,
    sock_path: Option<PathBuf>,
}

impl Endpoint {
    /// Bind a listening endpoint. `addr` is `tcp:HOST:PORT`, a bare
    /// `HOST:PORT` (TCP), or `unix:PATH`. Port 0 picks an ephemeral
    /// port; [`Endpoint::connect_addr`] reports the resolved one.
    pub fn bind(addr: &str) -> Result<Endpoint> {
        if let Some(path) = addr.strip_prefix("unix:") {
            return Endpoint::bind_unix(PathBuf::from(path));
        }
        let hostport = addr.strip_prefix("tcp:").unwrap_or(addr);
        let listener = TcpListener::bind(hostport)
            .with_context(|| format!("endpoint: binding tcp listener on {hostport}"))?;
        let local = listener
            .local_addr()
            .context("endpoint: no local addr")?;
        Ok(Endpoint {
            listener: Listener::Tcp(listener),
            connect_addr: format!("tcp:{local}"),
            sock_path: None,
        })
    }

    #[cfg(unix)]
    fn bind_unix(path: PathBuf) -> Result<Endpoint> {
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("endpoint: binding unix socket {}", path.display()))?;
        Ok(Endpoint {
            listener: Listener::Unix(listener),
            connect_addr: format!("unix:{}", path.display()),
            sock_path: Some(path),
        })
    }

    #[cfg(not(unix))]
    fn bind_unix(path: PathBuf) -> Result<Endpoint> {
        bail!(
            "endpoint: unix socket address {} on a platform without unix sockets",
            path.display()
        )
    }

    /// The default local endpoint `spawn_fleet` uses: a Unix domain
    /// socket where available (loopback TCP when `SOCCER_PROCESS_SOCKET
    /// =tcp` forces it, or on platforms without Unix sockets).
    pub(crate) fn bind_local() -> Result<Endpoint> {
        let nonce = ENDPOINT_NONCE.fetch_add(1, Ordering::Relaxed);
        #[cfg(unix)]
        {
            let force_tcp =
                matches!(std::env::var("SOCCER_PROCESS_SOCKET").as_deref(), Ok("tcp"));
            if !force_tcp {
                let path = std::env::temp_dir().join(format!(
                    "soccer-{}-ep{nonce}.sock",
                    std::process::id()
                ));
                return Endpoint::bind_unix(path);
            }
        }
        let _ = nonce; // tcp addresses need no nonce; the kernel picks the port
        Endpoint::bind("tcp:127.0.0.1:0")
    }

    /// The address workers pass to `soccer-machine --connect` —
    /// `tcp:IP:PORT` or `unix:PATH`. (When bound on a wildcard address
    /// like `0.0.0.0`, substitute a host the workers can actually
    /// route to.)
    pub fn connect_addr(&self) -> &str {
        &self.connect_addr
    }

    /// Run the bounded accept/registration loop until every spec in
    /// `specs` has been claimed by a dialing worker and shipped its
    /// shards. Links return in worker-index order.
    ///
    /// `register_timeout` bounds how long the bring-up tolerates **no
    /// registration progress** (no new index claimed, no handshake
    /// completed, and no connection queued or mid-handshake) — the
    /// deadline refreshes on every step forward and never fires while a
    /// handshake is in flight, so a big fleet whose handshakes queue
    /// behind the bounded pool is not penalized for shipping shards,
    /// while a stalled bring-up still fails after one quiet window.
    /// Each individual handshake read AND write is additionally
    /// bounded, so neither a connected-but-silent dialer nor one that
    /// stops reading mid-ship can hang bring-up; and once the window
    /// has expired, new connections are no longer admitted (in-flight
    /// ones drain), so an endless trickle of stray probes cannot defer
    /// the deadline forever. `doomed` is the launcher's liveness probe —
    /// called with the per-index claimed mask on every loop tick, it
    /// lets `spawn_fleet` fail fast when a child it spawned died before
    /// registering; launchers with no such knowledge pass `|_| Ok(())`.
    ///
    /// Any refused registration (bad magic, version mismatch, duplicate
    /// or out-of-range index) fails the whole bring-up fast: the typed
    /// refusal is sent back to the dialer and returned as the error.
    /// A connection that dies *before* saying hello, though, is network
    /// noise (port scanners and health checks are routine on a
    /// non-loopback listener): it is logged and dropped, and the loop
    /// keeps accepting. The caller owns teardown of whatever it
    /// launched.
    pub fn accept_fleet(
        &self,
        specs: Vec<WorkerSpec>,
        register_timeout: Duration,
        mut doomed: impl FnMut(&[bool]) -> Result<()>,
    ) -> Result<Vec<WorkerLink>> {
        let expected = specs.len();
        if expected == 0 {
            bail!("endpoint: a fleet needs at least one worker");
        }
        for (i, spec) in specs.iter().enumerate() {
            if spec.index != i {
                bail!(
                    "endpoint: spec {i} claims worker index {} (specs must be in index order)",
                    spec.index
                );
            }
            if spec.machines.is_empty() {
                bail!("worker {i}: spec hosts zero machines");
            }
        }
        self.listener.set_nonblocking(true)?;

        // a handshake thread claims spec i by take()-ing its slot; a
        // second dialer claiming i finds it empty -> DuplicateIndex.
        // The per-index slots share one rank: no thread ever holds two.
        let slots: Vec<RankedMutex<Option<WorkerSpec>>> = specs
            .into_iter()
            .map(|s| RankedMutex::new(REGISTRATION_SPEC, Some(s)))
            .collect();
        let claimed: Vec<AtomicBool> = (0..expected).map(|_| AtomicBool::new(false)).collect();
        let links: RankedMutex<Vec<Option<WorkerLink>>> =
            RankedMutex::new(REGISTRATION_LINKS, (0..expected).map(|_| None).collect());
        let done = AtomicUsize::new(0);
        let inflight = AtomicUsize::new(0);
        let first_err: RankedMutex<Option<Error>> = RankedMutex::new(REGISTRATION_ERROR, None);
        let closing = AtomicBool::new(false);
        let (tx, rx) = mpsc::channel::<Stream>();
        let rx = RankedMutex::new(REGISTRATION_QUEUE, rx);

        let outcome: Result<()> = std::thread::scope(|s| {
            let pool = (expected + SPARE_REGISTRATION_THREADS).min(MAX_REGISTRATION_CONCURRENCY);
            for i in 0..pool {
                let worker = || loop {
                    // dequeue under the lock, handshake outside it:
                    // registrations run concurrently across the pool
                    let stream = {
                        let guard = rx.lock();
                        match guard.recv() {
                            Ok(stream) => stream,
                            Err(_) => return, // window closed
                        }
                    };
                    // the window closed while this connection sat in the
                    // queue: it is a stray — drop it (EOF to the dialer)
                    // instead of spending a handshake timeout on it
                    if closing.load(Ordering::Acquire) {
                        inflight.fetch_sub(1, Ordering::AcqRel);
                        continue;
                    }
                    let outcome = register_one(stream, &slots, &claimed);
                    inflight.fetch_sub(1, Ordering::AcqRel);
                    match outcome {
                        Ok(Registration::Registered(index, link)) => {
                            links.lock()[index] = Some(link);
                            done.fetch_add(1, Ordering::Release);
                        }
                        Ok(Registration::Noise(e)) => {
                            eprintln!(
                                "soccer: endpoint ignored a connection that closed before \
                                 registering: {e}"
                            );
                        }
                        Err(e) => {
                            let mut g = first_err.lock();
                            if g.is_none() {
                                *g = Some(e);
                            }
                        }
                    }
                };
                std::thread::Builder::new()
                    .name(format!("soccer-register-{i}"))
                    .spawn_scoped(s, worker)
                    .expect("spawn registration thread");
            }

            let mut deadline = Instant::now() + register_timeout;
            let mut last_progress = 0usize;
            let result = loop {
                if let Some(e) = first_err.lock().take() {
                    break Err(e);
                }
                if done.load(Ordering::Acquire) == expected {
                    break Ok(());
                }
                let mask: Vec<bool> =
                    claimed.iter().map(|c| c.load(Ordering::Acquire)).collect();
                if let Err(e) = doomed(&mask) {
                    break Err(e);
                }
                // the deadline is a STALL bound: a new claim or a
                // finished handshake buys a fresh window, and it never
                // fires while a connection is queued or mid-handshake —
                // a legitimately long shard ship is progress, bounded by
                // its own per-read timeout, not by this one. Noise
                // connections defer the deadline only while they occupy
                // a slot (at most HELLO_TIMEOUT each); they never
                // refresh the window, so a scanner-probed listener whose
                // workers never arrive still times out.
                let progress =
                    mask.iter().filter(|&&c| c).count() + done.load(Ordering::Acquire);
                if progress > last_progress {
                    last_progress = progress;
                    deadline = Instant::now() + register_timeout;
                }
                if Instant::now() >= deadline {
                    if inflight.load(Ordering::Acquire) == 0 {
                        let got = mask.iter().filter(|&&c| c).count();
                        break Err(format_err!(
                            "endpoint: only {got}/{expected} workers registered \
                             ({register_timeout:?} with no registration progress)"
                        ));
                    }
                    // window expired but handshakes are still in flight:
                    // DRAIN, don't admit. Every in-flight step is
                    // time-bounded (hello/write/ack timeouts), so the
                    // drain terminates: either one registers (progress —
                    // the window refreshes and admission resumes) or
                    // inflight hits zero and the stall fails above. Not
                    // admitting here is what stops an endless trickle of
                    // stray probes from deferring the deadline forever.
                    std::thread::sleep(Duration::from_millis(2));
                    continue;
                }
                match self.listener.try_accept() {
                    Ok(Some(stream)) => {
                        // counted until its handshake resolves, so the
                        // stall check above cannot fire on a connection
                        // that is merely waiting for a pool thread
                        inflight.fetch_add(1, Ordering::AcqRel);
                        let _ = tx.send(stream);
                    }
                    Ok(None) => std::thread::sleep(Duration::from_millis(2)),
                    Err(e) => break Err(e),
                }
            };
            // close the registration window: queued strays are dropped
            // undialed, in-flight handshakes finish (each read is
            // HANDSHAKE_TIMEOUT-bounded), then the pool exits and the
            // scope join returns
            closing.store(true, Ordering::Release);
            drop(tx);
            result
        });

        outcome?;
        let links = links
            .into_inner()
            .into_iter()
            .enumerate()
            .map(|(i, l)| l.ok_or_else(|| format_err!("worker {i}: registration incomplete")))
            .collect::<Result<Vec<WorkerLink>>>()?;
        Ok(links)
    }

    /// Re-open the registration path after bring-up: admit dialers
    /// claiming the **dead** worker indices in `rejoin_specs` (each
    /// spec carries the retained shards and a fresh RNG stream to
    /// re-ship), for up to `window`. Returns the links that actually
    /// registered, tagged with their worker index — fewer than asked
    /// is not an error; the caller decides whether to keep waiting.
    ///
    /// The handshake is byte-for-byte the bring-up one
    /// ([`register_one`]): hello → validate/claim → accept-ack →
    /// LoadShard → live acks — a relaunched crashed worker and a
    /// brand-new late joiner are mechanically identical, both just
    /// dial and claim an orphaned index. Unlike bring-up, a *refused*
    /// registration (live index → `DuplicateIndex`, out-of-range,
    /// version mismatch…) is logged and dropped, never an error: a
    /// stray dialer must not kill a running fleet. Handshakes run
    /// inline — rejoin churn is rare and per-step time-bounded, so a
    /// pool buys nothing here.
    pub(crate) fn accept_rejoins(
        &self,
        rejoin_specs: Vec<WorkerSpec>,
        workers_total: usize,
        window: Duration,
    ) -> Result<Vec<(usize, WorkerLink)>> {
        let expected = rejoin_specs.len();
        if expected == 0 {
            return Ok(Vec::new());
        }
        // full-fleet-width slot vector, occupied only at the dead
        // indices: a dialer claiming a live index finds its slot empty
        // and is refused as DuplicateIndex, exactly like bring-up
        let slots: Vec<RankedMutex<Option<WorkerSpec>>> = (0..workers_total)
            .map(|_| RankedMutex::new(REGISTRATION_SPEC, None))
            .collect();
        for spec in rejoin_specs {
            let index = spec.index;
            if index >= workers_total {
                bail!(
                    "endpoint: rejoin spec claims worker {index}, fleet has {workers_total}"
                );
            }
            if spec.machines.is_empty() {
                bail!("endpoint: rejoin spec for worker {index} hosts zero machines");
            }
            let mut slot = slots[index].lock();
            if slot.is_some() {
                bail!("endpoint: two rejoin specs claim worker {index}");
            }
            *slot = Some(spec);
        }
        let claimed: Vec<AtomicBool> =
            (0..workers_total).map(|_| AtomicBool::new(false)).collect();
        self.listener.set_nonblocking(true)?;
        let deadline = Instant::now() + window;
        let mut admitted: Vec<(usize, WorkerLink)> = Vec::new();
        while admitted.len() < expected {
            let stream = match self.listener.try_accept() {
                Ok(Some(stream)) => stream,
                Ok(None) => {
                    if Instant::now() >= deadline {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                    continue;
                }
                Err(e) => return Err(e),
            };
            match register_one(stream, &slots, &claimed) {
                Ok(Registration::Registered(index, link)) => admitted.push((index, link)),
                Ok(Registration::Noise(e)) => {
                    eprintln!(
                        "soccer: endpoint ignored a connection that closed before \
                         registering: {e}"
                    );
                }
                Err(e) => {
                    eprintln!("soccer: endpoint refused a post-bring-up dialer: {e}");
                }
            }
        }
        Ok(admitted)
    }
}

impl Drop for Endpoint {
    fn drop(&mut self) {
        if let Some(p) = self.sock_path.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Refuse a dialer: best-effort reject frame (so the worker dies loudly
/// with the coordinator's reason), then surface the refusal as the
/// bring-up error.
fn refuse(stream: &mut Stream, refusal: RegisterRefusal) -> Error {
    let _ = stream.send_frame(&protocol::encode_register_reject(&refusal));
    format_err!("registration refused: {refusal}")
}

/// Outcome of handling one accepted connection.
enum Registration {
    /// A worker claimed `index` and holds its shards: the ready link.
    Registered(usize, WorkerLink),
    /// The connection vanished before ever saying hello — routine
    /// network noise on a public listener (scanners, health checks),
    /// logged and dropped rather than failing bring-up.
    Noise(Error),
}

/// One registration handshake: hello → validate/claim → accept-ack →
/// LoadShard → live acks. A decoded-but-invalid hello or any post-claim
/// failure is an `Err` (fails bring-up); a connection that dies before
/// the hello is [`Registration::Noise`].
fn register_one(
    mut stream: Stream,
    slots: &[RankedMutex<Option<WorkerSpec>>],
    claimed: &[AtomicBool],
) -> Result<Registration> {
    // a real worker speaks immediately: bound the hello tightly (in
    // both time and claimed size) so a silent or garbage-spewing stray
    // frees its handshake thread fast and cannot make us allocate.
    // Writes are bounded too: a dialer that says hello and then stops
    // READING would otherwise wedge the shard ship forever once the
    // socket buffer fills — every handshake step must terminate.
    stream.set_read_timeout(Some(HELLO_TIMEOUT))?;
    stream.set_write_timeout(Some(HANDSHAKE_TIMEOUT))?;
    let mut sent = 0usize;
    let mut received = 0usize;

    let hello = match stream.recv_frame_bounded(HELLO_MAX_FRAME) {
        Ok(hello) => hello,
        Err(e) => return Ok(Registration::Noise(e)),
    };
    received += 4 + hello.len();
    // from here the reads can be bulky (the shard-ack follows a
    // possibly huge LoadShard decode): switch to the generous bound
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    let index = match protocol::decode_hello(&hello) {
        Ok(i) => i,
        Err(refusal) => return Err(refuse(&mut stream, refusal)),
    };
    if index as usize >= slots.len() {
        return Err(refuse(
            &mut stream,
            RegisterRefusal::IndexOutOfRange {
                index,
                workers: slots.len(),
            },
        ));
    }
    let index = index as usize;
    let taken = slots[index].lock().take();
    let Some(spec) = taken else {
        return Err(refuse(
            &mut stream,
            RegisterRefusal::DuplicateIndex {
                index: index as u64,
            },
        ));
    };
    claimed[index].store(true, Ordering::Release);

    let ack = protocol::encode_register_accept();
    stream
        .send_frame(&ack)
        .map_err(|e| e.context(format!("worker {index}: registration ack failed")))?;
    sent += 4 + ack.len();

    let shards = protocol::encode_load_shards(&spec.machines)?;
    stream
        .send_frame(&shards)
        .map_err(|e| e.context(format!("worker {index}: shipping shards failed")))?;
    sent += 4 + shards.len();

    let ack = stream
        .recv_frame()
        .map_err(|e| e.context(format!("worker {index}: no shard ack")))?;
    received += 4 + ack.len();
    let loaded = protocol::decode_live_acks(&ack)?;
    if loaded.len() != spec.machines.len() {
        bail!(
            "worker {index}: acked {} machines, coordinator shipped {}",
            loaded.len(),
            spec.machines.len()
        );
    }
    for (s, &n) in spec.machines.iter().zip(&loaded) {
        if n != s.shard.rows() {
            bail!(
                "worker {index}: machine {} loaded {n} rows, coordinator shipped {}",
                s.id,
                s.shard.rows()
            );
        }
    }
    // handshake done: the data plane blocks indefinitely by default (a
    // dead worker is an instant EOF; only SOCCER_PROCESS_TIMEOUT_SECS
    // opts into bounding slow computation)
    stream.set_read_timeout(read_timeout())?;
    stream.set_write_timeout(None)?;
    let link = WorkerLink::registered(index, stream, sent, received)
        .map_err(|e| e.context(format!("worker {index}: spawning link I/O thread")))?;
    Ok(Registration::Registered(index, link))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Matrix;
    use crate::transport::process::MachineSpec;
    use crate::util::rng::Pcg64;

    fn spec(index: usize) -> WorkerSpec {
        WorkerSpec {
            index,
            machines: vec![MachineSpec {
                id: index,
                rng: Pcg64::new(index as u64 + 1),
                shard: Matrix::zeros(2, 3),
            }],
        }
    }

    #[test]
    fn endpoint_bind_reports_a_dialable_tcp_addr() {
        let ep = Endpoint::bind("127.0.0.1:0").unwrap();
        let addr = ep.connect_addr().to_string();
        let hostport = addr.strip_prefix("tcp:").expect("tcp address");
        assert!(hostport.starts_with("127.0.0.1:"), "{addr}");
        // the listener really is there
        TcpStream::connect(hostport).expect("dial the endpoint");
    }

    #[test]
    #[cfg(unix)]
    fn endpoint_bind_unix_cleans_up_its_socket_file() {
        let path = std::env::temp_dir().join(format!(
            "soccer-endpoint-test-{}.sock",
            std::process::id()
        ));
        let ep = Endpoint::bind(&format!("unix:{}", path.display())).unwrap();
        assert!(path.exists());
        assert_eq!(ep.connect_addr(), &format!("unix:{}", path.display()));
        drop(ep);
        assert!(!path.exists(), "drop removes the socket file");
    }

    #[test]
    fn accept_fleet_rejects_malformed_spec_lists() {
        let ep = Endpoint::bind("127.0.0.1:0").unwrap();
        let err = ep
            .accept_fleet(Vec::new(), Duration::from_millis(50), |_| Ok(()))
            .err()
            .expect("bring-up must fail");
        assert!(err.to_string().contains("at least one"), "{err}");
        // out-of-order indices are refused before any I/O
        let ep = Endpoint::bind("127.0.0.1:0").unwrap();
        let err = ep
            .accept_fleet(vec![spec(1)], Duration::from_millis(50), |_| Ok(()))
            .err()
            .expect("bring-up must fail");
        assert!(err.to_string().contains("index order"), "{err}");
    }

    #[test]
    fn accept_fleet_times_out_when_nobody_dials() {
        let ep = Endpoint::bind("127.0.0.1:0").unwrap();
        let t0 = Instant::now();
        let err = ep
            .accept_fleet(vec![spec(0)], Duration::from_millis(100), |_| Ok(()))
            .err()
            .expect("bring-up must fail");
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert!(err.to_string().contains("0/1 workers"), "{err}");
    }

    #[test]
    fn accept_fleet_fails_fast_when_the_launcher_says_doomed() {
        let ep = Endpoint::bind("127.0.0.1:0").unwrap();
        let t0 = Instant::now();
        let err = ep
            .accept_fleet(vec![spec(0)], Duration::from_secs(30), |claimed| {
                assert_eq!(claimed, &[false]);
                Err(format_err!("launcher: child died before registering"))
            })
            .err()
            .expect("bring-up must fail");
        assert!(t0.elapsed() < Duration::from_secs(5), "doomed probe ignored");
        assert!(err.to_string().contains("child died"), "{err}");
    }
}
