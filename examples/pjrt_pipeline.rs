//! The three-layer pipeline, explicitly: load the AOT-compiled
//! JAX/Pallas artifacts (L1/L2) into the PJRT runtime and drive the
//! SOCCER coordinator (L3) entirely through them — Python is not
//! involved at any point of this run.
//!
//! Requires building with `--features pjrt` and running `make
//! artifacts` first; the default build prints how to enable it.
//!
//!   cargo run --release --features pjrt --example pjrt_pipeline

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("pjrt_pipeline drives SOCCER through the PJRT runtime. Enabling it needs");
    eprintln!("the out-of-tree `xla` PJRT bindings crate added as a dependency plus");
    eprintln!("`make artifacts`, then rebuild with `--features pjrt` (see README.md).");
    eprintln!("The default build is native-only.");
}

#[cfg(feature = "pjrt")]
fn main() {
    use soccer::clustering::LloydKMeans;
    use soccer::coordinator::{run_soccer, SoccerParams};
    use soccer::data::gaussian::{generate, GaussianMixtureSpec};
    use soccer::machines::Fleet;
    use soccer::runtime::{Engine, NativeEngine, PjrtRuntime};
    use soccer::util::rng::Pcg64;

    let rt = PjrtRuntime::load_default().expect("run `make artifacts` first");
    println!("PJRT platform: {}", rt.platform());

    let n = 30_000;
    let k = 10;
    let gm = generate(&GaussianMixtureSpec::paper(n, k), &mut Pcg64::new(5));
    let mut fleet = Fleet::new(&gm.points, 16, 6);
    let params = SoccerParams::new(k, 0.1);

    // L3 over PJRT: every machine-side distance computation (removal
    // masks, cost evaluation) executes the lowered Pallas kernel
    let out = run_soccer(&mut fleet, &rt, &params, &LloydKMeans::default(), 7);
    println!(
        "pjrt engine:   rounds={} cost={:.4} T_total={:.3}s",
        out.rounds, out.cost, out.total_secs
    );
    let execs = rt.exec_counts.borrow().clone();
    println!("artifact executions: {execs:?}");
    assert!(execs.values().sum::<usize>() > 0, "PJRT path must be exercised");

    // same run on the native engine for comparison
    fleet.reset();
    let out_native = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 7);
    println!(
        "native engine: rounds={} cost={:.4} T_total={:.3}s",
        out_native.rounds, out_native.cost, out_native.total_secs
    );
    println!(
        "cost agreement pjrt/native: {:.3}x ({})",
        out.cost / out_native.cost,
        NativeEngine.name()
    );
}
