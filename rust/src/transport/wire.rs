//! Wire codec for the coordinator-model protocol: flat little-endian
//! encodings of the few shapes that cross a link — `Matrix` point sets,
//! quota/count scalars, and f32/f64 vectors.
//!
//! Layout (all little-endian):
//!
//! - `u32`/`u64`/`f32`/`f64` — their plain LE byte representation,
//! - `Matrix` — `u32 rows`, `u32 cols`, then `rows·cols` f32 values
//!   row-major ([`MATRIX_HEADER`] = 8 bytes of header, `4·rows·cols`
//!   bytes of data — the paper's "points × 4·d" unit, plus the header),
//! - `[f32]`/`[f64]` — `u32 len` then the values.
//!
//! Every coordinator→machine request starts with a u32 opcode
//! ([`OP_TAG`] bytes) and a u32 machine-routing field ([`MACHINE_TAG`]
//! bytes; see [`crate::transport::protocol`]) so a worker process that
//! hosts several machines knows which step to run and on which. Replies
//! stay tag-free — the protocol is phase-synchronous, both ends know
//! which reply shape comes next — and a shape mismatch is a protocol
//! bug that panics with a message rather than limping on. Oversized
//! dimensions that would not fit the u32 headers are a [`WireError`]
//! (a `usize` silently truncated by `as u32` decodes as garbage on the
//! other end).
//!
//! f32/f64 values round-trip bit-exactly, which is what makes
//! `DirectTransport` vs wired runs byte-identical in outcome.

use crate::core::Matrix;
use std::fmt;

/// Bytes every frame costs on the wire beyond its payload: the u32
/// length prefix the transports add.
pub const FRAME_OVERHEAD: usize = 4;

/// Encoded-`Matrix` header size (u32 rows + u32 cols).
pub const MATRIX_HEADER: usize = 8;

/// Bytes every coordinator→machine request spends on its u32 opcode.
pub const OP_TAG: usize = 4;

/// Bytes every coordinator→machine request spends on its u32
/// machine-routing field (a machine id, or `protocol::ALL_MACHINES` on
/// a broadcast). The field is what lets one worker process host many
/// machines; it is present — and metered — on every wired transport so
/// the modes stay byte-identical.
pub const MACHINE_TAG: usize = 4;

/// A value that cannot be encoded: a dimension or length exceeds the
/// u32 wire header. Returned instead of silently truncating with
/// `as u32` (which would decode as garbage on the receiving end).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireError {
    what: &'static str,
    value: usize,
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "wire: {} {} exceeds the u32 header (max {}); shard the payload",
            self.what,
            self.value,
            u32::MAX
        )
    }
}

impl std::error::Error for WireError {}

/// Checked `usize → u32` for wire headers — the fix for the silent
/// `as u32` truncation bug on matrices/vectors with ≥ 2³² entries.
pub fn u32_header(value: usize, what: &'static str) -> Result<u32, WireError> {
    u32::try_from(value).map_err(|_| WireError { what, value })
}

/// Encoded size of a `rows × cols` matrix, header included.
pub fn matrix_bytes(rows: usize, cols: usize) -> usize {
    MATRIX_HEADER + 4 * rows * cols
}

/// Builds one frame payload.
#[derive(Default)]
pub struct FrameWriter {
    buf: Vec<u8>,
}

impl FrameWriter {
    pub fn new() -> FrameWriter {
        FrameWriter { buf: Vec::new() }
    }

    pub fn with_capacity(bytes: usize) -> FrameWriter {
        FrameWriter {
            buf: Vec::with_capacity(bytes),
        }
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_matrix(&mut self, m: &Matrix) -> Result<(), WireError> {
        let rows = u32_header(m.rows(), "matrix rows")?;
        let cols = u32_header(m.cols(), "matrix cols")?;
        self.buf.reserve(matrix_bytes(m.rows(), m.cols()));
        self.put_u32(rows);
        self.put_u32(cols);
        for v in m.data() {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    pub fn put_f32s(&mut self, vs: &[f32]) -> Result<(), WireError> {
        let len = u32_header(vs.len(), "f32 vector length")?;
        self.buf.reserve(4 + 4 * vs.len());
        self.put_u32(len);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    pub fn put_f64s(&mut self, vs: &[f64]) -> Result<(), WireError> {
        let len = u32_header(vs.len(), "f64 vector length")?;
        self.buf.reserve(4 + 8 * vs.len());
        self.put_u32(len);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    /// Raw bytes, no length header — for trailing variable-length
    /// content (e.g. a registration-reject reason) where the frame
    /// boundary already delimits it.
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Decodes one frame payload in write order.
pub struct FrameReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> FrameReader<'a> {
    pub fn new(buf: &'a [u8]) -> FrameReader<'a> {
        FrameReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> &'a [u8] {
        assert!(
            self.pos + n <= self.buf.len(),
            "wire: truncated frame (want {n} bytes at {}, frame is {})",
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        s
    }

    pub fn get_u32(&mut self) -> u32 {
        u32::from_le_bytes(self.take(4).try_into().expect("4 bytes"))
    }

    pub fn get_u64(&mut self) -> u64 {
        u64::from_le_bytes(self.take(8).try_into().expect("8 bytes"))
    }

    pub fn get_f32(&mut self) -> f32 {
        f32::from_le_bytes(self.take(4).try_into().expect("4 bytes"))
    }

    pub fn get_f64(&mut self) -> f64 {
        f64::from_le_bytes(self.take(8).try_into().expect("8 bytes"))
    }

    pub fn get_matrix(&mut self) -> Matrix {
        let rows = self.get_u32() as usize;
        let cols = self.get_u32() as usize;
        let raw = self.take(4 * rows * cols);
        let mut data = Vec::with_capacity(rows * cols);
        for chunk in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes(chunk.try_into().expect("4 bytes")));
        }
        Matrix::from_vec(data, rows, cols)
    }

    pub fn get_f32s(&mut self) -> Vec<f32> {
        let len = self.get_u32() as usize;
        let raw = self.take(4 * len);
        raw.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect()
    }

    pub fn get_f64s(&mut self) -> Vec<f64> {
        let len = self.get_u32() as usize;
        let raw = self.take(8 * len);
        raw.chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().expect("8 bytes")))
            .collect()
    }

    /// Bytes not yet consumed (0 when a frame was fully decoded).
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Everything not yet consumed, as one slice (twin of
    /// [`FrameWriter::put_bytes`]: trailing content the frame boundary
    /// delimits).
    pub fn rest(&mut self) -> &'a [u8] {
        self.take(self.buf.len() - self.pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = FrameWriter::new();
        w.put_u32(7);
        w.put_u64(u64::MAX - 3);
        w.put_f32(-1.5e-7);
        w.put_f64(std::f64::consts::PI);
        let frame = w.finish();
        assert_eq!(frame.len(), 4 + 8 + 4 + 8);
        let mut r = FrameReader::new(&frame);
        assert_eq!(r.get_u32(), 7);
        assert_eq!(r.get_u64(), u64::MAX - 3);
        assert_eq!(r.get_f32(), -1.5e-7);
        assert_eq!(r.get_f64(), std::f64::consts::PI);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn matrix_roundtrip_is_bit_exact() {
        let m = Matrix::from_vec(vec![1.0, f32::MIN_POSITIVE, -0.0, 3.25e8, 5.0, -6.5], 3, 2);
        let mut w = FrameWriter::new();
        w.put_matrix(&m).unwrap();
        let frame = w.finish();
        assert_eq!(frame.len(), matrix_bytes(3, 2));
        let mut r = FrameReader::new(&frame);
        let back = r.get_matrix();
        assert_eq!(back, m);
        // bit-exactness, not just PartialEq (−0.0 == 0.0 would pass ==)
        for (a, b) in back.data().iter().zip(m.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_matrix_keeps_cols() {
        let m = Matrix::zeros(0, 5);
        let mut w = FrameWriter::new();
        w.put_matrix(&m).unwrap();
        let frame = w.finish();
        assert_eq!(frame.len(), MATRIX_HEADER);
        let mut r = FrameReader::new(&frame);
        let back = r.get_matrix();
        assert!(back.is_empty());
        assert_eq!(back.cols(), 5);
    }

    #[test]
    fn vec_roundtrip() {
        let mut w = FrameWriter::new();
        w.put_f32s(&[1.0, -2.0]).unwrap();
        w.put_f64s(&[0.25, 1e300, -0.0]).unwrap();
        let frame = w.finish();
        let mut r = FrameReader::new(&frame);
        assert_eq!(r.get_f32s(), vec![1.0, -2.0]);
        assert_eq!(r.get_f64s(), vec![0.25, 1e300, -0.0]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    #[should_panic(expected = "truncated frame")]
    fn truncated_frame_panics() {
        let mut w = FrameWriter::new();
        w.put_u32(3); // claims 3 f32s follow
        let frame = w.finish();
        let mut r = FrameReader::new(&frame);
        r.get_f32s();
    }

    #[test]
    fn u32_header_boundary() {
        // a ≥2^32-entry payload cannot be allocated in a test, so the
        // checked conversion itself is the unit under test: the exact
        // boundary passes, one past it is a typed WireError instead of
        // the old silent `as u32` truncation
        assert_eq!(u32_header(0, "rows"), Ok(0));
        assert_eq!(u32_header(u32::MAX as usize, "rows"), Ok(u32::MAX));
        let err = u32_header(u32::MAX as usize + 1, "matrix rows").unwrap_err();
        let text = err.to_string();
        assert!(text.contains("matrix rows"), "{text}");
        assert!(text.contains("exceeds the u32 header"), "{text}");
    }
}
