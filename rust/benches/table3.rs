//! Table 3: ε = 0.01 — a tiny coordinator. SOCCER's worst-case bound is
//! 99 rounds but it actually uses 2–4 (KDD: ~7–11); k-means|| is run
//! until its cost is within 2% of SOCCER's and needs more rounds and
//! far more machine time.

use soccer::bench_support::experiments::*;
use soccer::bench_support::{fmt_val, Table};
use soccer::config::ExperimentConfig;
use soccer::util::json::Json;

fn main() {
    let n = soccer::bench_support::harness::bench_n(100_000);
    let reps = soccer::bench_support::harness::bench_reps(3);
    let full = std::env::var("SOCCER_BENCH_FULL").is_ok();
    let ks: Vec<usize> = if full { vec![25, 100] } else { vec![25] };
    let eps = 0.01;
    let kmpar_cap = 15;

    let mut table = Table::new(
        "Table 3: eps=0.01 (worst-case 99 rounds). km|| run until within 2% of SOCCER",
        &["Dataset", "k", "|P1|", "R", "Cost", "T_mach(s)", "km|| R", "km|| T(s)"],
    );
    let mut log_rows = Vec::new();

    for dataset in ["gaussian", "higgs", "census", "kdd", "bigcross"] {
        for &k in &ks {
            let cfg = ExperimentConfig {
                dataset: dataset.into(),
                n,
                repetitions: reps,
                machines: 50,
                ..Default::default()
            };
            let engine_box = EngineBox::by_name(&cfg.engine);
            let engine = engine_box.engine();
            let mut fleet = build_fleet(&cfg, k);

            let soc = soccer_cell(&mut fleet, engine, &cfg, k, eps);
            let until = kmeans_par_until_cost(
                &mut fleet,
                engine,
                &cfg,
                k,
                soc.cost.mean(),
                0.02,
                kmpar_cap,
            );
            let (km_r, km_t) = match until {
                Some((r, t)) => (r.to_string(), format!("{t:.4}")),
                None => (format!(">{kmpar_cap}"), "-".into()),
            };
            table.row(vec![
                dataset.into(),
                k.to_string(),
                soc.p1_size.to_string(),
                format!("{:.1}", soc.rounds.mean()),
                fmt_val(soc.cost.mean()),
                format!("{:.4}", soc.t_machine.mean()),
                km_r.clone(),
                km_t.clone(),
            ]);
            log_rows.push(Json::obj(vec![
                ("dataset", Json::str(dataset)),
                ("k", Json::num(k as f64)),
                ("soccer_rounds", Json::num(soc.rounds.mean())),
                ("soccer_cost", Json::num(soc.cost.mean())),
                ("soccer_t", Json::num(soc.t_machine.mean())),
                ("kmpar_rounds", Json::str(km_r)),
            ]));
        }
    }
    table.print();
    println!(
        "note: worst-case bound for eps=0.01 is {} rounds; observed means above.",
        soccer::coordinator::SoccerParams::new(25, eps).worst_case_rounds()
    );
    let path = soccer::bench_support::harness::write_log(
        "table3",
        Json::obj(vec![("n", Json::num(n as f64)), ("rows", Json::Arr(log_rows))]),
    );
    println!("log: {}", path.display());
}
