//! Head-to-head on a heavy-tailed KDD-like workload: SOCCER (adaptive
//! stopping) vs k-means|| stopped after 1..5 rounds — the paper's core
//! experimental comparison on its hardest dataset.
//!
//!   cargo run --release --example compare_kmeans_parallel [-- --n 200000 --k 25]

use soccer::bench_support::experiments::*;
use soccer::bench_support::{fmt_val, Table};
use soccer::config::ExperimentConfig;
use soccer::runtime::NativeEngine;
use soccer::util::cli::Cli;

fn main() {
    let cli = Cli::new("compare_kmeans_parallel", "SOCCER vs k-means|| on the KDD surrogate")
        .opt("n", Some("100000"), "dataset size")
        .opt("k", Some("25"), "clusters")
        .opt("eps", Some("0.1"), "SOCCER epsilon")
        .opt("reps", Some("3"), "repetitions");
    let args = cli.parse_env();

    let cfg = ExperimentConfig {
        dataset: "kdd".into(),
        n: args.usize("n", 100_000),
        repetitions: args.usize("reps", 3),
        machines: 50,
        ..Default::default()
    };
    let k = args.usize("k", 25);
    let eps = args.f64("eps", 0.1);

    let mut fleet = build_fleet(&cfg, k);
    println!(
        "KDD-like surrogate: {} points x {} dims, heavy-tailed (see DESIGN.md §4)",
        cfg.n,
        fleet.dim()
    );

    let soc = soccer_cell(&mut fleet, &NativeEngine, &cfg, k, eps);
    let km = kmeans_par_cells(&mut fleet, &NativeEngine, &cfg, k, &[1, 2, 3, 4, 5]);

    let mut t = Table::new(
        &format!("SOCCER (eps={eps}) vs k-means|| (k={k}, {} reps)", cfg.repetitions),
        &["ALG", "rounds", "cost (mean±std)", "T_mach(s)"],
    );
    t.row(vec![
        "SOCCER".into(),
        soc.rounds.fmt(),
        soc.cost.fmt(),
        soc.t_machine.fmt(),
    ]);
    for cell in &km {
        t.row(vec![
            format!("k-means|| R={}", cell.rounds),
            cell.rounds.to_string(),
            cell.cost.fmt(),
            cell.t_machine.fmt(),
        ]);
    }
    t.print();
    let km5 = km.last().unwrap();
    println!(
        "SOCCER reaches cost {} in {:.1} adaptive rounds; k-means|| needs 5 fixed rounds for {} at {:.1}x machine time.",
        fmt_val(soc.cost.mean()),
        soc.rounds.mean(),
        fmt_val(km5.cost.mean()),
        km5.t_machine.mean() / soc.t_machine.mean().max(1e-12)
    );
}
