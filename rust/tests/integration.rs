//! Cross-module integration: full algorithm runs over datasets, fleet
//! invariants under each algorithm, config-driven execution, telemetry
//! consistency.

use soccer::baselines::{run_centralized, Eim11, KmeansParallel};
use soccer::bench_support::experiments::{build_fleet, make_blackbox, soccer_cell};
use soccer::clustering::LloydKMeans;
use soccer::config::ExperimentConfig;
use soccer::coordinator::{run_soccer, SoccerParams};
use soccer::data;
use soccer::machines::Fleet;
use soccer::runtime::NativeEngine;
use soccer::util::rng::Pcg64;

fn small_cfg(dataset: &str) -> ExperimentConfig {
    ExperimentConfig {
        dataset: dataset.into(),
        n: 12_000,
        machines: 10,
        repetitions: 1,
        ..Default::default()
    }
}

#[test]
fn soccer_runs_on_every_dataset() {
    for dataset in data::DATASET_NAMES {
        let cfg = small_cfg(dataset);
        let mut fleet = build_fleet(&cfg, 8);
        let params = SoccerParams::new(8, 0.15);
        let out = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 1);
        assert!(out.cost.is_finite() && out.cost >= 0.0, "{dataset}");
        assert!(out.rounds <= params.max_rounds, "{dataset}");
        assert!(out.final_centers.rows() <= 8, "{dataset}");
        assert!(out.final_centers.cols() == fleet.dim(), "{dataset}");
        // cost must beat the trivial 1-center clustering
        let ds = data::by_name(dataset, cfg.n, 8, cfg.seed);
        let trivial = run_centralized(&ds.points, 1, &LloydKMeans::default(), 2);
        assert!(out.cost <= trivial.cost, "{dataset}: {} > {}", out.cost, trivial.cost);
    }
}

#[test]
fn soccer_cost_within_factor_of_centralized() {
    for dataset in ["gaussian", "higgs", "bigcross"] {
        let cfg = small_cfg(dataset);
        let mut fleet = build_fleet(&cfg, 10);
        let params = SoccerParams::new(10, 0.15);
        let out = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 3);
        let ds = data::by_name(dataset, cfg.n, 10, cfg.seed);
        let central = run_centralized(&ds.points, 10, &LloydKMeans::default(), 4);
        // Theorem 4.1's worst factor at beta~9 is ~I*(80*9+44); in
        // practice SOCCER lands within a small constant -- require 10x
        assert!(
            out.cost <= 10.0 * central.cost.max(1e-9),
            "{dataset}: soccer {} vs central {}",
            out.cost,
            central.cost
        );
    }
}

#[test]
fn kmeans_parallel_improves_with_rounds_on_gaussian() {
    let cfg = small_cfg("gaussian");
    let mut fleet = build_fleet(&cfg, 10);
    let mut costs = Vec::new();
    for rounds in [1usize, 5] {
        fleet.reset();
        let km = KmeansParallel::new(10, rounds);
        costs.push(km.run(&mut fleet, &NativeEngine, &LloydKMeans::default(), 9).cost);
    }
    assert!(
        costs[1] < costs[0],
        "5 rounds {} should beat 1 round {}",
        costs[1],
        costs[0]
    );
}

#[test]
fn eim11_vs_soccer_broadcast() {
    let cfg = small_cfg("gaussian");
    let mut fleet = build_fleet(&cfg, 10);
    let params = SoccerParams::new(6, 0.15);
    let soc = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 11);
    fleet.reset();
    let eim = Eim11::new(6, 0.15).run(&mut fleet, &NativeEngine, &LloydKMeans::default(), 12);
    let soc_bcast: usize = soc.telemetry.rounds.iter().map(|r| r.broadcast).sum();
    let eim_bcast: usize = eim.telemetry.rounds.iter().map(|r| r.broadcast).sum();
    assert!(
        eim_bcast > 5 * soc_bcast.max(1),
        "EIM11 broadcast {eim_bcast} should dwarf SOCCER's {soc_bcast}"
    );
}

#[test]
fn fleet_partition_invariant_through_protocol() {
    let ds = data::by_name("census", 8_000, 5, 3);
    let mut fleet = Fleet::new(&ds.points, 7, 4);
    let n = fleet.total_live();
    let params = SoccerParams::new(5, 0.2);
    let out = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 5);
    // every point is accounted for: removed over rounds + drained = n
    let removed: usize = out.telemetry.rounds.iter().map(|r| r.removed).sum();
    let drained = out.telemetry.comm.to_coordinator
        - out.telemetry.rounds.iter().map(|r| r.sampled).sum::<usize>();
    assert_eq!(removed + drained, n, "partition invariant violated");
    assert_eq!(fleet.total_live(), 0);
    assert_eq!(fleet.total_original(), n);
}

#[test]
fn repetitions_are_deterministic_given_seed() {
    let cfg = small_cfg("higgs");
    let mut fleet = build_fleet(&cfg, 6);
    let params = SoccerParams::new(6, 0.2);
    fleet.reset();
    let a = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 77);
    fleet.reset();
    let b = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 77);
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.output_size, b.output_size);
    assert!((a.cost - b.cost).abs() <= 1e-9 * a.cost.max(1.0));
}

#[test]
fn experiment_executor_smoke() {
    let cfg = small_cfg("gaussian");
    let mut fleet = build_fleet(&cfg, 5);
    let cell = soccer_cell(&mut fleet, &NativeEngine, &cfg, 5, 0.2);
    assert_eq!(cell.cost.values.len(), cfg.repetitions);
    assert!(cell.cost.mean().is_finite());
}

#[test]
fn minibatch_blackbox_full_protocol() {
    let cfg = ExperimentConfig {
        blackbox: "minibatch".into(),
        ..small_cfg("gaussian")
    };
    let mut fleet = build_fleet(&cfg, 8);
    let params = SoccerParams::new(8, 0.15);
    let bb = make_blackbox(&cfg.blackbox);
    let out = run_soccer(&mut fleet, &NativeEngine, &params, bb.as_ref(), 13);
    assert!(out.cost.is_finite());
    assert!(out.rounds >= 1);
}

#[test]
fn zero_progress_safety_valve() {
    // adversarial: a huge duplicate mass plus far outliers; termination
    // must happen regardless (possibly via forced drain)
    let mut rng = Pcg64::new(1);
    let mut pts = soccer::Matrix::zeros(0, 2);
    for _ in 0..5000 {
        pts.push_row(&[0.0, 0.0]);
    }
    for _ in 0..200 {
        pts.push_row(&[rng.normal() as f32 * 1e6, rng.normal() as f32 * 1e6]);
    }
    let mut fleet = Fleet::new(&pts, 5, 2);
    let mut params = SoccerParams::new(3, 0.1);
    params.max_rounds = 6;
    let out = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 3);
    assert!(out.rounds <= 6);
    assert!(out.cost.is_finite());
}
