//! The machine-side half of the coordinator-model wire protocol.
//!
//! Every coordinator→machine request frame starts with a u32 [`Op`]
//! tag followed by the op's arguments; the machine executes the step
//! and sends back the op's (tag-free) reply frame. This module is the
//! single definition of both sides' frame layouts: the fleet builds
//! requests with [`request`], and *every* wired machine — an in-process
//! thread under `TransportKind::InProc`/`LoopbackTcp`, or a spawned
//! `soccer-machine` worker process under `TransportKind::Process` —
//! answers them through the same [`dispatch`]. That sharing is what
//! makes the three wired modes byte-identical on the wire and
//! bit-identical in outcome.
//!
//! Lifecycle frames ([`Op::LoadShard`], [`Op::Reset`], [`Op::Reseed`],
//! [`Op::Shutdown`], plus the worker's hello) exist only on
//! process-backed links: in-process fleets mutate their machines
//! directly. They are deliberately *not* metered by the fleet's
//! protocol byte counters — they are setup/teardown, not the paper's
//! communication — so a process fleet's measured protocol bytes equal
//! an in-process fleet's exactly.
//!
//! Machine-side timing: `dispatch` runs the `Machine` methods that
//! self-time, and the measured seconds travel back inside the reply
//! frames. On a process fleet those seconds are genuine other-process
//! wall time, not a simulation.

use crate::machines::Machine;
use crate::runtime::Engine;
use crate::transport::wire::{FrameReader, FrameWriter};
use crate::transport::Transport;
use crate::util::error::Result;
use crate::util::rng::Pcg64;
use crate::{bail, format_err};

/// First frame on a process link, worker → coordinator.
pub const HELLO_MAGIC: u32 = 0x534F_4343; // "SOCC"

/// Bumped whenever a frame layout changes; the coordinator refuses a
/// worker speaking a different version instead of decoding garbage.
pub const PROTOCOL_VERSION: u32 = 1;

/// Request opcodes. Data-plane ops are the fleet steps every wired
/// transport meters; lifecycle ops exist only on process links.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum Op {
    // ---- lifecycle (process links only; never metered) ----------------
    /// coordinator → worker at handshake: machine id, RNG state, shard
    LoadShard = 1,
    /// restore the pre-run shard and RNG stream (repetition replay)
    Reset = 2,
    /// restore the shard and install a fresh RNG stream
    Reseed = 3,
    /// drain the link and exit cleanly (replaces the thread join)
    Shutdown = 4,
    // ---- data plane (all wired transports; metered) --------------------
    SampleExactPair = 16,
    SampleBernoulliPair = 17,
    Remove = 18,
    Drain = 19,
    CostFull = 20,
    CountsFull = 21,
    CountsFullBelow = 22,
    PerPointCosts = 23,
    KmparInit = 24,
    KmparUpdate = 25,
    KmparSample = 26,
    UniformPoint = 27,
}

impl Op {
    pub fn from_u32(v: u32) -> Option<Op> {
        Some(match v {
            1 => Op::LoadShard,
            2 => Op::Reset,
            3 => Op::Reseed,
            4 => Op::Shutdown,
            16 => Op::SampleExactPair,
            17 => Op::SampleBernoulliPair,
            18 => Op::Remove,
            19 => Op::Drain,
            20 => Op::CostFull,
            21 => Op::CountsFull,
            22 => Op::CountsFullBelow,
            23 => Op::PerPointCosts,
            24 => Op::KmparInit,
            25 => Op::KmparUpdate,
            26 => Op::KmparSample,
            27 => Op::UniformPoint,
            _ => return None,
        })
    }
}

/// Start a request frame: the op tag, ready for the op's arguments.
pub fn request(op: Op) -> FrameWriter {
    let mut w = FrameWriter::new();
    w.put_u32(op as u32);
    w
}

/// The worker's opening frame: magic, protocol version, machine id.
pub fn encode_hello(id: u64) -> Vec<u8> {
    let mut w = FrameWriter::with_capacity(16);
    w.put_u32(HELLO_MAGIC);
    w.put_u32(PROTOCOL_VERSION);
    w.put_u64(id);
    w.finish()
}

/// Verify a hello frame and return the worker's machine id.
pub fn decode_hello(frame: &[u8]) -> Result<u64> {
    if frame.len() != 16 {
        bail!("process handshake: hello frame is {} bytes, want 16", frame.len());
    }
    let mut r = FrameReader::new(frame);
    let magic = r.get_u32();
    if magic != HELLO_MAGIC {
        bail!("process handshake: bad magic {magic:#010x} (not a soccer-machine?)");
    }
    let version = r.get_u32();
    if version != PROTOCOL_VERSION {
        bail!("process handshake: worker speaks protocol v{version}, coordinator v{PROTOCOL_VERSION}");
    }
    Ok(r.get_u64())
}

/// The shard-loading frame the coordinator ships right after the hello:
/// machine id, the machine's initial RNG state, and its data shard.
pub fn encode_load_shard(id: u64, rng: &Pcg64, shard: &crate::core::Matrix) -> Result<Vec<u8>> {
    let mut w = request(Op::LoadShard);
    w.put_u64(id);
    for word in rng.to_raw() {
        w.put_u64(word);
    }
    w.put_matrix(shard)?;
    Ok(w.finish())
}

/// Decode [`encode_load_shard`] into a ready [`Machine`], verifying the
/// id matches the one the worker was spawned with.
pub fn decode_load_shard(frame: &[u8], expect_id: u64) -> Result<Machine> {
    let mut r = FrameReader::new(frame);
    let op = r.get_u32();
    if Op::from_u32(op) != Some(Op::LoadShard) {
        bail!("worker expected a LoadShard frame, got op {op}");
    }
    let id = r.get_u64();
    if id != expect_id {
        bail!("shard frame is for machine {id}, this worker is machine {expect_id}");
    }
    let raw = [r.get_u64(), r.get_u64(), r.get_u64(), r.get_u64()];
    let shard = r.get_matrix();
    Ok(Machine::new(id as usize, shard, Pcg64::from_raw(raw)))
}

/// The ack closing a lifecycle exchange: the machine's live-point count
/// (the coordinator's size metadata comes from these).
pub fn encode_live_ack(n_live: usize) -> Vec<u8> {
    let mut w = FrameWriter::with_capacity(8);
    w.put_u64(n_live as u64);
    w.finish()
}

/// Execute one data-plane or lifecycle request on a machine and encode
/// the reply. This is the exact logic the PR-2 fleet ran in per-step
/// closures, now shared between in-process machine threads and the
/// `soccer-machine` worker loop.
pub fn dispatch(m: &mut Machine, req: &[u8], engine: &dyn Engine) -> Result<Vec<u8>> {
    let mut r = FrameReader::new(req);
    let op = Op::from_u32(r.get_u32()).ok_or_else(|| format_err!("unknown protocol op"))?;
    let mut w = FrameWriter::new();
    match op {
        Op::SampleExactPair => {
            let a = r.get_u64() as usize;
            let b = r.get_u64() as usize;
            let t1 = m.sample_exact(a);
            let t2 = m.sample_exact(b);
            w.put_matrix(&t1.value)?;
            w.put_matrix(&t2.value)?;
            w.put_f64(t1.secs + t2.secs);
        }
        Op::SampleBernoulliPair => {
            let alpha = r.get_f64();
            let t = m.sample_bernoulli_pair(alpha);
            w.put_matrix(&t.value.0)?;
            w.put_matrix(&t.value.1)?;
            w.put_f64(t.secs);
        }
        Op::Remove => {
            let v = r.get_f32();
            let centers = r.get_matrix();
            let t = m.remove_within(&centers, v, engine);
            w.put_u64(t.value as u64);
            w.put_f64(t.secs);
        }
        Op::Drain => {
            w.put_matrix(&m.drain())?;
        }
        Op::CostFull => {
            let centers = r.get_matrix();
            let t = m.cost_original(&centers, engine);
            w.put_f64(t.value);
            w.put_f64(t.secs);
        }
        Op::CountsFull => {
            let centers = r.get_matrix();
            let t = m.counts_original(&centers, engine);
            w.put_f64s(&t.value)?;
            w.put_f64(t.secs);
        }
        Op::CountsFullBelow => {
            let cutoff = r.get_f32();
            let centers = r.get_matrix();
            let t = m.counts_original_below(&centers, cutoff, engine);
            w.put_f64s(&t.value)?;
            w.put_f64(t.secs);
        }
        Op::PerPointCosts => {
            let centers = r.get_matrix();
            let t = m.per_point_costs_original(&centers, engine);
            w.put_f32s(&t.value)?;
        }
        Op::KmparInit => {
            let initial = r.get_matrix();
            let t = m.kmpar_init(&initial, engine);
            w.put_f64(t.value);
            w.put_f64(t.secs);
        }
        Op::KmparUpdate => {
            let centers = r.get_matrix();
            let t = m.kmpar_update(&centers, engine);
            w.put_f64(t.value);
            w.put_f64(t.secs);
        }
        Op::KmparSample => {
            let l = r.get_f64();
            let phi = r.get_f64();
            let t = m.kmpar_sample(l, phi);
            w.put_matrix(&t.value)?;
            w.put_f64(t.secs);
        }
        Op::UniformPoint => {
            let idx = r.get_u64() as usize;
            w.put_matrix(&m.live().select(&[idx]))?;
        }
        Op::Reset => {
            m.reset();
            return Ok(encode_live_ack(m.n_live()));
        }
        Op::Reseed => {
            let raw = [r.get_u64(), r.get_u64(), r.get_u64(), r.get_u64()];
            m.reset();
            m.reseed(Pcg64::from_raw(raw));
            return Ok(encode_live_ack(m.n_live()));
        }
        Op::LoadShard | Op::Shutdown => {
            bail!("op {op:?} is a link-lifecycle frame, not a dispatchable step");
        }
    }
    Ok(w.finish())
}

/// The worker's request loop: answer dispatched requests until a
/// [`Op::Shutdown`] frame arrives (clean exit) or the peer disconnects
/// (also a clean exit — the coordinator dropping the link IS the
/// shutdown signal when it tears down without the courtesy frame).
pub fn serve(link: &mut dyn Transport, m: &mut Machine, engine: &dyn Engine) -> Result<()> {
    loop {
        let req = match link.recv() {
            Ok(req) => req,
            // a vanished peer is a normal end-of-service, not a panic
            Err(_) => return Ok(()),
        };
        if req.len() >= 4 && FrameReader::new(&req).get_u32() == Op::Shutdown as u32 {
            return Ok(());
        }
        let reply = dispatch(m, &req, engine)?;
        link.send(&reply)?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Matrix;
    use crate::runtime::NativeEngine;

    fn machine(n: usize) -> Machine {
        let mut rng = Pcg64::new(3);
        let data = (0..n * 2).map(|_| rng.normal() as f32).collect();
        Machine::new(0, Matrix::from_vec(data, n, 2), Pcg64::new(4))
    }

    #[test]
    fn op_tags_roundtrip() {
        for op in [
            Op::LoadShard,
            Op::Reset,
            Op::Reseed,
            Op::Shutdown,
            Op::SampleExactPair,
            Op::SampleBernoulliPair,
            Op::Remove,
            Op::Drain,
            Op::CostFull,
            Op::CountsFull,
            Op::CountsFullBelow,
            Op::PerPointCosts,
            Op::KmparInit,
            Op::KmparUpdate,
            Op::KmparSample,
            Op::UniformPoint,
        ] {
            assert_eq!(Op::from_u32(op as u32), Some(op));
        }
        assert_eq!(Op::from_u32(0), None);
        assert_eq!(Op::from_u32(999), None);
    }

    #[test]
    fn hello_roundtrip_and_rejections() {
        assert_eq!(decode_hello(&encode_hello(7)).unwrap(), 7);
        assert!(decode_hello(&[1, 2, 3]).is_err());
        let mut bad_magic = encode_hello(7);
        bad_magic[0] ^= 0xff;
        assert!(decode_hello(&bad_magic).is_err());
        let mut bad_version = encode_hello(7);
        bad_version[4] ^= 0xff;
        assert!(decode_hello(&bad_version).is_err());
    }

    #[test]
    fn load_shard_rebuilds_the_exact_machine() {
        let shard = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 3, 2);
        let rng = Pcg64::new(11);
        let frame = encode_load_shard(5, &rng, &shard).unwrap();
        let mut worker = decode_load_shard(&frame, 5).unwrap();
        let mut local = Machine::new(5, shard, rng);
        // identical shard, identical RNG stream
        assert_eq!(worker.original(), local.original());
        let a = worker.sample_exact(2).value;
        let b = local.sample_exact(2).value;
        assert_eq!(a, b);
        // id mismatch is refused
        let frame = encode_load_shard(5, &Pcg64::new(11), worker.original()).unwrap();
        assert!(decode_load_shard(&frame, 6).is_err());
    }

    #[test]
    fn dispatch_matches_direct_machine_calls() {
        let eng = NativeEngine;
        let mut a = machine(200);
        let mut b = machine(200);
        let centers = Matrix::from_rows(&[&[0.0, 0.0]]);

        // remove: same removed count over the wire frames
        let mut w = request(Op::Remove);
        w.put_f32(0.5);
        w.put_matrix(&centers).unwrap();
        let reply = dispatch(&mut a, &w.finish(), &eng).unwrap();
        let mut r = FrameReader::new(&reply);
        let removed_wire = r.get_u64() as usize;
        let removed_direct = b.remove_within(&centers, 0.5, &eng).value;
        assert_eq!(removed_wire, removed_direct);

        // cost: bit-identical f64
        let mut w = request(Op::CostFull);
        w.put_matrix(&centers).unwrap();
        let reply = dispatch(&mut a, &w.finish(), &eng).unwrap();
        let cost_wire = FrameReader::new(&reply).get_f64();
        let cost_direct = b.cost_original(&centers, &eng).value;
        assert_eq!(cost_wire.to_bits(), cost_direct.to_bits());

        // reset ack carries the restored live size
        let reply = dispatch(&mut a, &request(Op::Reset).finish(), &eng).unwrap();
        assert_eq!(FrameReader::new(&reply).get_u64(), 200);
    }

    #[test]
    fn dispatch_rejects_lifecycle_and_unknown_ops() {
        let eng = NativeEngine;
        let mut m = machine(10);
        assert!(dispatch(&mut m, &request(Op::Shutdown).finish(), &eng).is_err());
        let mut w = FrameWriter::new();
        w.put_u32(999);
        assert!(dispatch(&mut m, &w.finish(), &eng).is_err());
    }
}
