//! Theorem 7.2: the Bachem-style hard instance. k-means|| cannot reach a
//! finite approximation factor in fewer than k-1 rounds (OPT = 0, so any
//! positive cost is an infinite factor); SOCCER finds the optimal
//! clustering in ONE round.

use soccer::baselines::KmeansParallel;
use soccer::bench_support::{fmt_val, Table};
use soccer::clustering::{weighted, LloydKMeans};
use soccer::coordinator::{run_soccer, SoccerParams};
use soccer::data::hard_instance;
use soccer::machines::Fleet;
use soccer::runtime::NativeEngine;
use soccer::util::json::Json;
use soccer::util::rng::Pcg64;

fn main() {
    let n0 = soccer::bench_support::harness::bench_n(20_000);
    let mut table = Table::new(
        "Theorem 7.2: hard instance (OPT = 0)",
        &["k", "SOCCER rounds", "SOCCER cost", "km|| cost @R=1", "@R=k/2", "@R=k-1", "@R=k"],
    );
    let mut log_rows = Vec::new();

    for k in [5usize, 10, 15] {
        let inst = hard_instance::generate(k, n0);
        let mut fleet = Fleet::new(&inst.points, 10, 42);

        // SOCCER: one round, optimal (zero) cost expected
        let params = SoccerParams::new(k, 0.2);
        let soc = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 1);

        // k-means|| snapshots at R = 1, k/2, k-1, k
        let rounds_grid = [1usize, (k / 2).max(1), k - 1, k];
        fleet.reset();
        let mut rng = Pcg64::new(7);
        let km = KmeansParallel::new(k, k);
        let (snaps, _, _) = km.run_with_snapshots(&mut fleet, &NativeEngine, &rounds_grid, &mut rng);
        let mut km_costs = Vec::new();
        for snap in &snaps {
            let counts = fleet.counts_full(&snap.centers_pre, &NativeEngine);
            let reduced = weighted::reduce_with_weights(
                &snap.centers_pre,
                &counts.value,
                k,
                &LloydKMeans::default(),
                &mut rng,
            );
            km_costs.push(fleet.cost_full(&reduced, &NativeEngine).value);
        }

        table.row(vec![
            k.to_string(),
            soc.rounds.to_string(),
            fmt_val(soc.cost),
            fmt_val(km_costs[0]),
            fmt_val(km_costs[1]),
            fmt_val(km_costs[2]),
            fmt_val(km_costs[3]),
        ]);
        log_rows.push(Json::obj(vec![
            ("k", Json::num(k as f64)),
            ("soccer_rounds", Json::num(soc.rounds as f64)),
            ("soccer_cost", Json::num(soc.cost)),
            ("kmpar_cost_r1", Json::num(km_costs[0])),
            ("kmpar_cost_rk", Json::num(km_costs[3])),
        ]));
    }
    table.print();
    println!("expected: SOCCER cost = 0 after 1 round; k-means|| cost > 0 until ~k-1 rounds.");
    let path = soccer::bench_support::harness::write_log(
        "theorem72",
        Json::obj(vec![("rows", Json::Arr(log_rows))]),
    );
    println!("log: {}", path.display());
}
