//! `soccer-lint` — run the in-tree invariant lint pass over `src/`
//! (or over the directories given as arguments) and fail with exit
//! code 1 on any violation. CI runs this next to the test suite; see
//! `soccer::analysis` for the rules and the waiver pragma.

use soccer::analysis::{lint_tree, rules};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!("usage: soccer-lint [DIR ...]   (default: the crate's src/)");
        println!("rules:");
        for rule in rules::all() {
            println!("  {:<14} {}", rule.name, rule.description);
        }
        println!("waive in place with: // lint: allow(<rule>) <reason>");
        return ExitCode::SUCCESS;
    }
    let roots: Vec<PathBuf> = if args.is_empty() {
        vec![Path::new(env!("CARGO_MANIFEST_DIR")).join("src")]
    } else {
        args.iter().map(PathBuf::from).collect()
    };
    let mut total = 0usize;
    for root in &roots {
        match lint_tree(root) {
            Ok(violations) => {
                for v in &violations {
                    // prefix with the root so terminal hyperlinks work
                    // when linting somewhere other than the cwd
                    println!("{}/{v}", root.display());
                }
                total += violations.len();
            }
            Err(e) => {
                eprintln!("soccer-lint: cannot read {}: {e}", root.display());
                return ExitCode::FAILURE;
            }
        }
    }
    if total == 0 {
        println!(
            "soccer-lint: clean ({} rule{} over {})",
            rules::all().len(),
            if rules::all().len() == 1 { "" } else { "s" },
            roots
                .iter()
                .map(|r| r.display().to_string())
                .collect::<Vec<_>>()
                .join(", ")
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("soccer-lint: {total} violation{}", if total == 1 { "" } else { "s" });
        ExitCode::FAILURE
    }
}
