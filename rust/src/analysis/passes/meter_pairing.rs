//! `meter-pairing`: every data-plane frame emission must be metered.
//! The transport's byte accounting (`up_bytes`/`down_bytes` on the
//! channel, `sent_bytes` on round results) is how round-size claims in
//! the paper reproduction are audited, so a `send_frame`/`submit` site
//! that skips accounting silently under-reports wire traffic.
//!
//! A site passes if its enclosing function visibly accounts bytes
//! (touches a counter field or a `+=`-updated `sent`/`received`
//! tally), or is an explicit lifecycle/handshake path — `LoadShard`,
//! `Reset`, `Reseed`, `Shutdown`, `Heartbeat`, `ExportState` and
//! `AttachShards` frames and the registration handshake (bring-up or
//! rejoin) are deliberately unmetered, they are not round traffic
//! (see `WiredChannel::control`). Everything else fires and needs
//! either accounting or a reviewed `// lint: allow(meter-pairing)`
//! waiver.

use super::super::{AnalysisUnit, Violation};
use super::{violation, Pass};
use crate::analysis::lexer::TokKind;

/// Counter fields and calls that count as byte accounting.
const ACCOUNTING_IDENTS: [&str; 6] = [
    "up_bytes",
    "down_bytes",
    "sent_bytes",
    "bytes_sent",
    "fetch_add",
    "CommStats",
];

/// `sent += …` / `received += …` style tallies.
const TALLY_IDENTS: [&str; 2] = ["sent", "received"];

/// Ops whose frames are lifecycle control traffic, not round data.
/// The elastic set (v4) — `Heartbeat` probes, `ExportState` migration
/// reads, `AttachShards` adoption — is lifecycle too: recovery traffic
/// is measured off the links' raw counters (`Fleet::reship_bytes`),
/// never the protocol meters.
const LIFECYCLE_OPS: [&str; 7] = [
    "LoadShard",
    "Reset",
    "Reseed",
    "Shutdown",
    "Heartbeat",
    "ExportState",
    "AttachShards",
];

/// Handshake encoders: a function building these frames is part of
/// registration (bring-up or rejoin) or of the elastic lifecycle,
/// which happens outside any round.
const HANDSHAKE_ENCODERS: [&str; 6] = [
    "encode_hello",
    "encode_load_shards",
    "encode_live_ack",
    "encode_live_acks",
    "encode_heartbeat",
    "encode_attach_shards",
];

/// Functions that are the lifecycle seam itself: `control` is the
/// deliberately unmetered one-op round (see transport/channel.rs).
const UNMETERED_LIFECYCLE_FNS: [&str; 1] = ["control"];

pub(super) fn check(pass: &Pass, units: &[AnalysisUnit]) -> Vec<Violation> {
    let mut out = Vec::new();
    for unit in units {
        let t = &unit.tokens;
        for j in 1..t.len() {
            let is_site = t[j - 1].is_punct(".")
                && t.get(j + 1).is_some_and(|x| x.is_punct("("))
                && (t[j].is_ident("send_frame")
                    || (t[j].is_ident("submit") && unit.path.starts_with("transport/")));
            if !is_site {
                continue;
            }
            let Some(f) = unit.index.enclosing_fn(j) else {
                continue;
            };
            // the primitives themselves, and pure pass-throughs named
            // after them (`WorkerLink::submit` → `LinkIo::submit`), are
            // metered at their call sites, not inside
            if f.name == "send_frame" || f.name == "submit" {
                continue;
            }
            if fn_is_metered_or_lifecycle(unit, f) {
                continue;
            }
            out.extend(violation(
                pass,
                unit,
                t[j].line,
                format!(
                    "`{}` in fn `{}` has no byte accounting and is not a \
                     lifecycle/handshake path",
                    t[j].text, f.name
                ),
            ));
        }
    }
    out
}

fn fn_is_metered_or_lifecycle(unit: &AnalysisUnit, f: &crate::analysis::index::FnItem) -> bool {
    if UNMETERED_LIFECYCLE_FNS.contains(&f.name.as_str()) {
        return true;
    }
    let t = &unit.tokens;
    for j in f.body.clone() {
        if t[j].kind != TokKind::Ident {
            continue;
        }
        let text = t[j].text.as_str();
        if ACCOUNTING_IDENTS.contains(&text) {
            return true;
        }
        // `sent += …`: the endpoint's own tallies (`+=` lexes as two puncts)
        if TALLY_IDENTS.contains(&text)
            && t.get(j + 1).is_some_and(|x| x.is_punct("+"))
            && t.get(j + 2).is_some_and(|x| x.is_punct("="))
        {
            return true;
        }
        // lifecycle op literal anywhere in the fn marks it a control path
        if text == "Op"
            && t.get(j + 1).is_some_and(|x| x.is_punct("::"))
            && t.get(j + 2)
                .is_some_and(|x| LIFECYCLE_OPS.contains(&x.text.as_str()))
        {
            return true;
        }
        if text.starts_with("encode_register") || HANDSHAKE_ENCODERS.contains(&text) {
            return true;
        }
    }
    false
}
