//! The pipelined data plane, pinned end to end (the CI `pipeline_`
//! release gate): with one persistent I/O thread per worker link and
//! the coordinator folding replies in slot order as each worker drains,
//! every observable — SOCCER outcomes, byte meters, crash semantics —
//! must be exactly what the barriered plane produced. The InProc fleet
//! runs the Local arm (whose meters are pinned byte-for-byte by the
//! channel's unit tests), so InProc ≡ Process here is the regression
//! chain back to the pre-pipelining meters.

#![cfg(unix)]

use soccer::clustering::LloydKMeans;
use soccer::coordinator::{run_soccer, SoccerParams};
use soccer::machines::Fleet;
use soccer::prop_assert;
use soccer::runtime::NativeEngine;
use soccer::transport::TransportKind;
use soccer::util::proptest::forall;
use soccer::util::rng::Pcg64;
use soccer::Matrix;

/// Point the fleet at the worker binary cargo built for this test run
/// (same pattern as tests/end_to_end.rs; `Once` because tests run on
/// parallel threads and concurrent setenv is UB on glibc).
fn use_test_worker_binary() {
    static SET: std::sync::Once = std::sync::Once::new();
    SET.call_once(|| std::env::set_var("SOCCER_MACHINE_BIN", env!("CARGO_BIN_EXE_soccer-machine")));
}

fn blob_points(n: usize, k: usize, rng: &mut Pcg64) -> Matrix {
    let mut pts = Matrix::zeros(n, 4);
    for i in 0..n {
        let c = rng.below(k);
        for v in pts.row_mut(i) {
            *v = (c as f64 * 20.0 + rng.normal()) as f32;
        }
    }
    pts
}

/// Randomized (n, m, machines_per_worker, seed) parity: a Direct, an
/// InProc and a packed Process fleet — the latter running pipelined
/// rounds over persistent links — produce bit-identical SOCCER
/// outcomes, and the wired meters agree to the byte. Pipelining folds
/// worker replies in slot order, which is machine order under the
/// contiguous packing, so FP accumulation order (and thus every bit)
/// is preserved.
#[test]
fn pipeline_randomized_transport_parity() {
    use_test_worker_binary();
    forall(
        "pipelined-transport-parity",
        3,
        31,
        |g| {
            let n = g.int(600, 2_000);
            let m = g.int(2, 6);
            let mpw = g.int(1, 4);
            let k = g.int(2, 4);
            let seed = g.rng.below(1 << 20) as u64;
            (n, m, mpw, k, seed)
        },
        |&(n, m, mpw, k, seed)| {
            let pts = blob_points(n, k, &mut Pcg64::new(seed));
            let params = SoccerParams::new(k, 0.2);
            let mut direct = Fleet::new(&pts, m, seed + 1);
            let mut inproc = Fleet::with_transport(&pts, m, seed + 1, TransportKind::InProc)
                .map_err(|e| e.to_string())?;
            let mut packed = Fleet::with_placement(&pts, m, seed + 1, TransportKind::Process, mpw)
                .map_err(|e| format!("packed fleet spawn: {e}"))?;

            let out_d = run_soccer(&mut direct, &NativeEngine, &params, &LloydKMeans::default(), seed + 2);
            let out_i = run_soccer(&mut inproc, &NativeEngine, &params, &LloydKMeans::default(), seed + 2);
            let out_p = run_soccer(&mut packed, &NativeEngine, &params, &LloydKMeans::default(), seed + 2);

            prop_assert!(out_d.c_out == out_p.c_out, "C_out drifted direct vs process");
            prop_assert!(
                out_d.final_centers == out_p.final_centers,
                "final centers drifted direct vs process"
            );
            prop_assert!(out_d.rounds == out_p.rounds, "round count drifted");
            prop_assert!(
                out_d.cost.to_bits() == out_p.cost.to_bits(),
                "cost bits drifted direct vs process"
            );
            prop_assert!(
                out_i.cost.to_bits() == out_p.cost.to_bits(),
                "cost bits drifted inproc vs process"
            );
            let (ci, cp) = (&out_i.telemetry.comm, &out_p.telemetry.comm);
            prop_assert!(
                ci.bytes_to_coordinator == cp.bytes_to_coordinator,
                "uplink meters diverged: inproc {} vs pipelined process {}",
                ci.bytes_to_coordinator,
                cp.bytes_to_coordinator
            );
            prop_assert!(
                ci.bytes_broadcast == cp.bytes_broadcast,
                "downlink meters diverged: inproc {} vs pipelined process {}",
                ci.bytes_broadcast,
                cp.bytes_broadcast
            );
            prop_assert!(cp.bytes_to_coordinator > 0, "process fleet measured nothing");
            // the pipelined plane's round clocks: never negative, and a
            // local/direct fleet never accrues them
            for r in &out_p.telemetry.rounds {
                prop_assert!(
                    r.coordinator_idle_time >= 0.0 && r.coordinator_fold_time >= 0.0,
                    "negative coordinator clock in round {}",
                    r.round
                );
            }
            prop_assert!(
                out_d.telemetry.rounds.iter().all(|r| r.coordinator_idle_time == 0.0),
                "direct fleet accrued idle time"
            );
            Ok(())
        },
    );
}

/// The meters are a property of the protocol, not the placement: the
/// same data under every packing (one worker per machine, pairs, one
/// worker hosting everything) moves byte-for-byte the same traffic as
/// the InProc fleet — broadcasts metered once per exchange, uplinks per
/// reply — and lands on bit-identical outcomes.
#[test]
fn pipeline_meters_byte_equal_across_packings() {
    use_test_worker_binary();
    let m = 6usize;
    let k = 3usize;
    let pts = blob_points(1_200, k, &mut Pcg64::new(61));
    let params = SoccerParams::new(k, 0.2);

    let mut inproc =
        Fleet::with_transport(&pts, m, 62, TransportKind::InProc).expect("inproc fleet");
    let out_i = run_soccer(&mut inproc, &NativeEngine, &params, &LloydKMeans::default(), 63);
    let ci = &out_i.telemetry.comm;
    assert!(ci.bytes_to_coordinator > 0 && ci.bytes_broadcast > 0);

    for mpw in [1usize, 2, 3, m] {
        let mut packed = Fleet::with_placement(&pts, m, 62, TransportKind::Process, mpw)
            .unwrap_or_else(|e| panic!("process fleet (mpw={mpw}): {e}"));
        let out_p = run_soccer(&mut packed, &NativeEngine, &params, &LloydKMeans::default(), 63);
        let cp = &out_p.telemetry.comm;
        assert_eq!(
            ci.bytes_to_coordinator, cp.bytes_to_coordinator,
            "uplink bytes drifted at mpw={mpw}"
        );
        assert_eq!(
            ci.bytes_broadcast, cp.bytes_broadcast,
            "downlink bytes drifted at mpw={mpw}"
        );
        assert_eq!(out_i.c_out, out_p.c_out, "C_out drifted at mpw={mpw}");
        assert_eq!(
            out_i.cost.to_bits(),
            out_p.cost.to_bits(),
            "cost bits drifted at mpw={mpw}"
        );
        assert_eq!(out_i.rounds, out_p.rounds, "round count drifted at mpw={mpw}");
    }
}

/// The idle/fold clocks behind the new telemetry: a Direct fleet never
/// accrues them; a Process fleet accrues idle time monotonically across
/// exchanges (the coordinator really does block on worker replies), the
/// per-round shares logged by the coordinator sum to no more than the
/// channel totals, and `reset_wire_meter` — which zeroes the byte
/// meters between runs — leaves the clocks alone.
#[test]
fn pipeline_idle_clock_monotone_and_never_reset_by_meter() {
    use_test_worker_binary();
    let k = 3usize;
    let pts = blob_points(900, k, &mut Pcg64::new(71));
    let params = SoccerParams::new(k, 0.2);

    let mut direct = Fleet::new(&pts, 4, 72);
    let out_d = run_soccer(&mut direct, &NativeEngine, &params, &LloydKMeans::default(), 73);
    assert_eq!(direct.coord_io_secs(), (0.0, 0.0), "direct fleets have no I/O plane");
    assert_eq!(out_d.telemetry.coordinator_idle_time(), 0.0);
    assert_eq!(out_d.telemetry.coordinator_fold_time(), 0.0);

    let mut fleet =
        Fleet::with_placement(&pts, 4, 72, TransportKind::Process, 2).expect("process fleet");
    assert_eq!(fleet.coord_io_secs(), (0.0, 0.0), "clocks start at zero");
    let out_p = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 73);
    let (idle, fold) = fleet.coord_io_secs();
    assert!(idle > 0.0, "a process fleet must accrue idle time, got {idle}");
    assert!(fold >= 0.0);
    // the coordinator attributes per-round deltas; their sum can't
    // exceed the channel totals (evaluation traffic after the last
    // round accrues on the channel but belongs to no round)
    let logged_idle = out_p.telemetry.coordinator_idle_time();
    let logged_fold = out_p.telemetry.coordinator_fold_time();
    assert!(logged_idle >= 0.0 && logged_idle <= idle + 1e-9, "{logged_idle} vs {idle}");
    assert!(logged_fold >= 0.0 && logged_fold <= fold + 1e-9, "{logged_fold} vs {fold}");

    fleet.reset_wire_meter();
    assert_eq!(fleet.wire_bytes(), (0, 0), "meters reset");
    let after = fleet.coord_io_secs();
    assert!(
        after.0 == idle && after.1 == fold,
        "reset_wire_meter must not touch the monotone clocks"
    );
}

/// Chaos under pipelining: SIGKILL a packed worker (out-of-band, as a
/// real crash would be) after it has participated in one pipelined
/// exchange. The next rounds must not wedge the coordinator's collect
/// loop: every machine the worker hosted downgrades to dead, and the
/// completed run is a bit-exact twin of a fleet whose dead machines
/// simply hold empty shards — a crashed process loses exactly its
/// shards, nothing else.
#[test]
fn pipeline_chaos_sigkill_mid_run_downgrades_and_matches_twin() {
    use_test_worker_binary();

    let spec = soccer::data::gaussian::GaussianMixtureSpec::paper(3_000, 3);
    let gm = soccer::data::gaussian::generate(&spec, &mut Pcg64::new(81));
    let m = 6usize;
    // 3 machines per worker: workers host [0,1,2] and [3,4,5]
    let mut fleet = Fleet::with_placement(&gm.points, m, 82, TransportKind::Process, 3)
        .expect("packed process fleet");

    // a healthy, RNG-free pipelined exchange first, so the crash lands
    // mid-protocol with the victim having already participated
    let d = gm.points.cols();
    let centers = Matrix::from_rows(&[&vec![0.0f32; d][..]]);
    let counts = fleet.counts_full(&centers, &NativeEngine).value;
    assert_eq!(counts[0] as usize, 3_000);

    // SIGKILL the worker hosting machines 3..6, behind the
    // coordinator's back
    let pids = fleet.worker_pids();
    assert_eq!(pids[3], pids[5], "machines 3..6 share a worker");
    let victim = pids[4].expect("worker alive");
    let status = std::process::Command::new("kill")
        .args(["-9", &victim.to_string()])
        .status()
        .expect("spawn kill");
    assert!(status.success(), "kill -9 failed");

    // the pipelined collect loop must observe the dead link and move
    // on within the watchdog window, never hang the coordinator
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        let centers = Matrix::from_rows(&[&vec![0.0f32; d][..]]);
        let counts = fleet.counts_full(&centers, &NativeEngine).value;
        let dead = fleet.dead_machines();
        let survivors = fleet.total_original();
        let params = SoccerParams::new(3, 0.2);
        let out = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 84);
        tx.send((counts, dead, survivors, out)).expect("report");
    });
    let (counts, dead, survivors, out_p) = rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("coordinator deadlocked after worker crash");
    handle.join().expect("watchdog thread");
    // ALL three hosted machines died with the process (500 points each)
    assert_eq!(dead, 3);
    assert_eq!(survivors, 1_500);
    assert_eq!(counts[0] as usize, 1_500);

    // bit-exact twin: same machine count and RNG stream assignment,
    // machines 3..6 holding empty shards from the start
    let mut shards = gm.points.split_rows(m);
    for shard in shards.iter_mut().skip(3) {
        *shard = Matrix::zeros(0, d);
    }
    let mut twin = Fleet::from_shards(shards, 82);
    let params = SoccerParams::new(3, 0.2);
    let out_t = run_soccer(&mut twin, &NativeEngine, &params, &LloydKMeans::default(), 84);
    assert_eq!(out_p.c_out, out_t.c_out);
    assert_eq!(out_p.final_centers, out_t.final_centers);
    assert_eq!(out_p.rounds, out_t.rounds);
    assert_eq!(out_p.cost.to_bits(), out_t.cost.to_bits());
    assert_eq!(out_p.cost_c_out.to_bits(), out_t.cost_c_out.to_bits());
}
