//! Ablation: PJRT (AOT JAX/Pallas artifacts) vs native rust distance
//! engine — microbench of the three artifact ops plus an end-to-end
//! SOCCER run under each engine. This is the §Perf anchor for L3 vs the
//! runtime path. Requires `--features pjrt` + `make artifacts`; without
//! the feature the target still builds and explains how to enable it.

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("ablate_runtime compares the PJRT and native engines.");
    eprintln!("Enabling it needs the out-of-tree `xla` PJRT bindings crate added as a");
    eprintln!("dependency plus `make artifacts`, then `cargo bench --features pjrt`");
    eprintln!("(see the pjrt feature notes in README.md).");
}

#[cfg(feature = "pjrt")]
fn main() {
    pjrt_ablation::run();
}

#[cfg(feature = "pjrt")]
mod pjrt_ablation {

use soccer::bench_support::{fmt_val, Table};
use soccer::clustering::LloydKMeans;
use soccer::coordinator::{run_soccer, SoccerParams};
use soccer::data::gaussian::{generate, GaussianMixtureSpec};
use soccer::machines::Fleet;
use soccer::runtime::{Engine, NativeEngine, PjrtRuntime};
use soccer::util::json::Json;
use soccer::util::rng::Pcg64;
use soccer::util::timer::timed;
use soccer::Matrix;

fn randmat(seed: u64, rows: usize, cols: usize) -> Matrix {
    let mut rng = Pcg64::new(seed);
    Matrix::from_vec((0..rows * cols).map(|_| rng.normal() as f32).collect(), rows, cols)
}

fn bench_engine(engine: &dyn Engine, pts: &Matrix, cen: &Matrix, reps: usize) -> (f64, f64) {
    // warmup (compilation for pjrt)
    let mut dist = Vec::new();
    let mut idx = Vec::new();
    engine.nearest(pts, cen, &mut dist, &mut idx);
    let (_, nearest_s) = timed(|| {
        for _ in 0..reps {
            engine.nearest(pts, cen, &mut dist, &mut idx);
        }
    });
    let mut keep = Vec::new();
    engine.removal_keep(pts, cen, 1.0, &mut keep);
    let (_, removal_s) = timed(|| {
        for _ in 0..reps {
            engine.removal_keep(pts, cen, 1.0, &mut keep);
        }
    });
    (nearest_s / reps as f64, removal_s / reps as f64)
}

pub fn run() {
    let n = soccer::bench_support::harness::bench_n(50_000);
    let reps = soccer::bench_support::harness::bench_reps(3);
    let pts = randmat(1, n, 15);
    let cen = randmat(2, 96, 15);
    let pjrt = PjrtRuntime::load_default().expect("run `make artifacts`");

    let (nat_near, nat_rem) = bench_engine(&NativeEngine, &pts, &cen, reps);
    let (pj_near, pj_rem) = bench_engine(&pjrt, &pts, &cen, reps);

    let flops = 2.0 * n as f64 * 96.0 * 15.0;
    let mut table = Table::new(
        &format!("Runtime ablation: nearest/removal over {n}x15 pts, 96 centers"),
        &["engine", "nearest (s)", "GFLOP/s", "removal (s)"],
    );
    table.row(vec![
        "native".into(),
        format!("{nat_near:.4}"),
        format!("{:.2}", flops / nat_near / 1e9),
        format!("{nat_rem:.4}"),
    ]);
    table.row(vec![
        "pjrt".into(),
        format!("{pj_near:.4}"),
        format!("{:.2}", flops / pj_near / 1e9),
        format!("{pj_rem:.4}"),
    ]);
    table.print();

    // end-to-end SOCCER under each engine
    let gm = generate(&GaussianMixtureSpec::paper(n, 10), &mut Pcg64::new(3));
    let params = SoccerParams::new(10, 0.1);
    let mut fleet = Fleet::new(&gm.points, 20, 4);
    let out_nat = run_soccer(&mut fleet, &NativeEngine, &params, &LloydKMeans::default(), 5);
    fleet.reset();
    let out_pj = run_soccer(&mut fleet, &pjrt, &params, &LloydKMeans::default(), 5);

    let mut t2 = Table::new(
        "End-to-end SOCCER by engine",
        &["engine", "rounds", "cost", "T_total(s)"],
    );
    t2.row(vec![
        "native".into(),
        out_nat.rounds.to_string(),
        fmt_val(out_nat.cost),
        format!("{:.3}", out_nat.total_secs),
    ]);
    t2.row(vec![
        "pjrt".into(),
        out_pj.rounds.to_string(),
        fmt_val(out_pj.cost),
        format!("{:.3}", out_pj.total_secs),
    ]);
    t2.print();

    let path = soccer::bench_support::harness::write_log(
        "ablate_runtime",
        Json::obj(vec![
            ("native_nearest_s", Json::num(nat_near)),
            ("pjrt_nearest_s", Json::num(pj_near)),
            ("native_gflops", Json::num(flops / nat_near / 1e9)),
            ("pjrt_gflops", Json::num(flops / pj_near / 1e9)),
            ("e2e_native_s", Json::num(out_nat.total_secs)),
            ("e2e_pjrt_s", Json::num(out_pj.total_secs)),
        ]),
    );
    println!("log: {}", path.display());
}

}
