"""L1 kernel vs pure-jnp oracle — the core correctness signal.

hypothesis sweeps shapes and data regimes; every case asserts allclose
against kernels.ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import distance, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0, offset=0.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(offset, scale, shape).astype(np.float32))


def check(points, centers):
    d2, idx = distance.dist_argmin(points, centers)
    rd2, ridx = ref.dist_argmin_ref(points, centers)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(rd2), rtol=1e-4, atol=1e-5)
    # argmin may legitimately differ on exact ties; compare via distances.
    diff = np.asarray(points)[:, None, :] - np.asarray(centers)[None, :, :]
    all_d2 = (diff * diff).sum(-1)
    picked = all_d2[np.arange(len(points)), np.asarray(idx)]
    np.testing.assert_allclose(picked, np.asarray(rd2), rtol=1e-4, atol=1e-5)


# --- hypothesis sweeps -----------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 3),
    d=st.integers(1, 17),
    k=st.integers(1, 19),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_shapes(n_blocks, d, k, seed):
    n = distance.BLOCK_N * n_blocks
    check(rand((n, d), seed), rand((k, d), seed + 1))


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([1, 2, 7, 63, 128, 255]),
    d=st.integers(1, 8),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_small_n_single_block(n, d, k, seed):
    # n <= BLOCK_N runs as a single block without padding.
    check(rand((n, d), seed), rand((k, d), seed + 1))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([1e-3, 1.0, 1e3]))
def test_kernel_scale_regimes(seed, scale):
    # catastrophic-cancellation regime: tight clusters far from origin. The
    # MXU formulation ||x||^2 - 2xc + ||c||^2 has absolute error on the
    # order of ||x||^2 * eps_f32 — the documented tradeoff vs the (x-c)^2
    # form (which cannot use the MXU). Tolerance reflects that bound.
    offset = 100.0
    pts = rand((256, 8), seed, scale=scale, offset=offset)
    cen = rand((5, 8), seed + 1, scale=scale, offset=offset)
    d2, _ = distance.dist_argmin(pts, cen)
    assert np.all(np.asarray(d2) >= 0.0), "clamp must kill negative distances"
    rd2, _ = ref.dist_argmin_ref(pts, cen)
    norm_sq = 8 * (offset**2 + scale**2)
    atol = 32 * np.finfo(np.float32).eps * norm_sq  # cancellation bound
    np.testing.assert_allclose(np.asarray(d2), np.asarray(rd2), rtol=1e-3, atol=atol)


# --- directed edge cases ---------------------------------------------------

def test_point_on_center_is_zero():
    cen = rand((4, 6), 0)
    pts = jnp.concatenate([cen, rand((252, 6), 1)])
    d2, idx = distance.dist_argmin(pts, cen)
    np.testing.assert_allclose(np.asarray(d2[:4]), 0.0, atol=1e-6)
    assert list(np.asarray(idx[:4])) == [0, 1, 2, 3]


def test_k_equals_one():
    pts, cen = rand((256, 3), 2), rand((1, 3), 3)
    check(pts, cen)


def test_sentinel_center_padding_never_wins():
    # The rust runtime pads the center axis with far sentinels; verify.
    pts = rand((256, 4), 4)
    real = rand((3, 4), 5)
    sentinel = jnp.full((5, 4), 1.0e17, jnp.float32)
    cen = jnp.concatenate([real, sentinel])
    d2, idx = distance.dist_argmin(pts, cen)
    assert int(np.asarray(idx).max()) < 3
    rd2, _ = ref.dist_argmin_ref(pts, real)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(rd2), rtol=1e-4)


def test_zero_pad_feature_axis_preserves_distances():
    pts, cen = rand((256, 5), 6), rand((4, 5), 7)
    pad = lambda a, w: jnp.pad(a, ((0, 0), (0, w)))
    d2a, _ = distance.dist_argmin(pts, cen)
    d2b, _ = distance.dist_argmin(pad(pts, 11), pad(cen, 11))
    np.testing.assert_allclose(np.asarray(d2a), np.asarray(d2b), rtol=1e-5)


def test_non_divisible_n_raises():
    with pytest.raises(ValueError):
        distance.dist_argmin(rand((300, 4), 8), rand((3, 4), 9))


def test_vmem_footprint_fits_main_shape():
    # main artifact shape must fit VMEM with double buffering (16 MB).
    fp = distance.vmem_footprint_bytes(d=64, k=256)
    assert 2 * fp < 16 * 1024 * 1024
    assert distance.mxu_flops_per_step(64, 256) == 2 * 256 * 256 * 64
